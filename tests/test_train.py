"""Training-substrate tests: convergence, microbatch equivalence, gradient
compression, schedules."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.optim import adamw, grad_compress
from repro.train import train_step as TS

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  head_dim=16, param_dtype="float32",
                  compute_dtype="float32")


def _data(tc):
    return SyntheticLM(DataConfig(CFG.vocab_size, tc.seq_len,
                                  tc.global_batch, seed=1), CFG)


def test_loss_decreases():
    tc = TrainConfig(global_batch=8, seq_len=32, total_steps=25, lr=3e-3,
                     warmup_steps=5)
    step = jax.jit(TS.make_train_step(CFG, tc))
    state = TS.init_train_state(CFG, tc, jax.random.PRNGKey(0))
    data = _data(tc)
    params, opt, cs = state
    losses = []
    for i in range(tc.total_steps):
        params, opt, cs, m = step(params, opt, cs, data.batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::6]


def test_microbatch_grads_match_full_batch():
    """Gradient accumulation is exact (not an approximation)."""
    tc_full = TrainConfig(global_batch=8, seq_len=16, microbatch=0)
    tc_mb = TrainConfig(global_batch=8, seq_len=16, microbatch=2)
    params = T.init_params(CFG, jax.random.PRNGKey(0))
    batch = _data(tc_full).batch_at(0)

    loss_full = TS.make_loss(CFG, tc_full)
    (l1, _), g1 = jax.value_and_grad(loss_full, has_aux=True)(params, batch)

    # reuse the internal accumulation path
    step = TS.make_train_step(CFG, tc_mb)
    # grads_of is internal; compare through one optimizer step instead
    opt = adamw.init(params, tc_mb)
    cs = grad_compress.CompressState(error=jax.tree.map(
        lambda p: jnp.zeros((), jnp.float32), params))
    p2, _, _, m2 = step(params, opt, cs, batch)

    opt_f = adamw.init(params, tc_full)
    step_f = TS.make_train_step(CFG, tc_full)
    p1, _, _, m1 = step_f(params, opt_f, cs, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_grad_clip_bounds_update():
    g = {"w": jnp.ones((4, 4)) * 100.0}
    clipped, gn = adamw.clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(400.0)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_lr_schedule_shape():
    tc = TrainConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.lr_schedule(tc, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9          # warmup rises
    assert lrs[99] < lrs[50] < lrs[15]             # cosine decays
    assert lrs[99] >= 0.1 * 1e-3 - 1e-9            # floor


@pytest.mark.parametrize("scheme", ["int8", "topk"])
def test_grad_compress_error_feedback(scheme):
    """Error feedback: compressed-sum converges to the true sum (the
    residual never grows unboundedly)."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
    state = grad_compress.init(g)
    acc_true = jnp.zeros((64, 64))
    acc_comp = jnp.zeros((64, 64))
    for i in range(20):
        gi = {"w": jax.random.normal(jax.random.PRNGKey(i), (64, 64))}
        out, state = grad_compress.compress_grads(gi, state, scheme)
        acc_true += gi["w"]
        acc_comp += out["w"]
    # residual bounded by one step's worth of compression error
    resid = float(jnp.linalg.norm(acc_true - acc_comp))
    assert resid <= float(jnp.linalg.norm(state.error["w"])) + 1e-3


def test_wire_bytes_savings():
    params = {"w": jnp.zeros((1000, 1000))}
    full = grad_compress.wire_bytes(params, "none")
    assert grad_compress.wire_bytes(params, "int8") < 0.3 * full
    assert grad_compress.wire_bytes(params, "topk") < 0.05 * full


def test_data_pipeline_deterministic_and_resumable():
    dc = DataConfig(vocab_size=97, seq_len=16, global_batch=4, seed=7)
    a, b = SyntheticLM(dc), SyntheticLM(dc)
    np.testing.assert_array_equal(a.batch_at(5)["tokens"],
                                  b.batch_at(5)["tokens"])
    assert not np.array_equal(a.batch_at(5)["tokens"],
                              a.batch_at(6)["tokens"])
    # shards partition the stream deterministically
    s0 = SyntheticLM(dataclasses.replace(dc, n_shards=2, shard=0))
    s1 = SyntheticLM(dataclasses.replace(dc, n_shards=2, shard=1))
    assert not np.array_equal(s0.batch_at(0)["tokens"],
                              s1.batch_at(0)["tokens"])
