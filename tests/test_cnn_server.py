"""Batched CNN serving: the micro-batch coalescing front-end that exploits
the batch-amortized SA-FC dataflow (the CNN analogue of ServeEngine)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dataflow import FCPlan
from repro.core.engine import DispatchPolicy, Engine
from repro.models import cnn
from repro.serve.cnn_server import CNNRequest, CNNServer

RES, WIDTH = 67, 0.125


@pytest.fixture(scope="module")
def alexnet_params():
    return cnn.init_cnn("alexnet", jax.random.PRNGKey(0), in_res=RES,
                        width_mult=WIDTH)


def _requests(n, seed=0):
    rng = np.random.default_rng(seed)
    return [CNNRequest(uid=i,
                       image=rng.standard_normal((RES, RES, 3))
                       .astype(np.float32))
            for i in range(n)]


def test_server_coalesces_singles_into_one_dispatch(alexnet_params):
    """Acceptance: >= 3 single-image submissions ride ONE planner-preferred
    micro-batch dispatch, every FC layer in the wave's DispatchTrace
    carrying an FCPlan, resolved from the compiled batch-variant
    schedule."""
    srv = CNNServer("alexnet", alexnet_params, in_res=RES, width_mult=WIDTH,
                    max_batch=8)
    reqs = _requests(4)
    for r in reqs:
        srv.submit(r)
    done = srv.run()
    assert len(done) == 4 and all(r.done for r in done)
    assert len(srv.waves) == 1                     # one coalesced dispatch
    wave = srv.waves[0]
    assert wave.batch == 4 and wave.uids == (0, 1, 2, 3)
    fc_recs = wave.fc_records
    assert len(fc_recs) == 3                       # fc1..fc3 of AlexNet
    assert all(isinstance(r.fc_plan, FCPlan) for r in fc_recs)
    assert all(r.schedule == "hit" for r in fc_recs)
    # the whole wave resolved from the compiled batch-variant schedule
    assert wave.schedule_hits == len([r for r in wave.trace
                                      if r.schedule == "hit"])
    assert wave.schedule_hits >= 8                 # 5 convs + 3 fcs


def test_server_outputs_bitwise_equal_unbatched(alexnet_params):
    """Acceptance: batching changes traffic, never math — each request's
    logits are bitwise equal to its own unbatched forward (rows are
    independent in every kernel and the b<=16 batch variants pad to the
    same tiles)."""
    srv = CNNServer("alexnet", alexnet_params, in_res=RES, width_mult=WIDTH,
                    max_batch=8)
    reqs = _requests(3, seed=1)
    for r in reqs:
        srv.submit(r)
    srv.run()
    eng = Engine(backend="pallas", interpret=True)
    for r in reqs:
        single = cnn.cnn_forward("alexnet", alexnet_params,
                                 jnp.asarray(r.image)[None], eng=eng)
        np.testing.assert_array_equal(np.asarray(single)[0], r.logits)


def test_server_microbatch_is_planner_preferred(alexnet_params):
    """The admission size IS the planner's resident batch tile: a VMEM
    budget that cannot hold 64 samples shrinks the micro-batch to what
    one weight pass can amortize."""
    roomy = CNNServer("alexnet", alexnet_params, in_res=RES,
                      width_mult=WIDTH, max_batch=64)
    assert roomy.microbatch == 64
    tight_eng = Engine(backend="pallas", interpret=True,
                       policy=DispatchPolicy(vmem_budget=200 * 1024))
    tight = CNNServer("alexnet", alexnet_params, in_res=RES,
                      width_mult=WIDTH, max_batch=64, engine=tight_eng)
    assert tight.microbatch < 64
    # and it matches the plan of the dominant FC layer exactly
    k, n = max(((p["w"].shape) for s, p in
                zip(cnn.NETWORKS["alexnet"][0], alexnet_params)
                if s.kind == "fc"), key=lambda s: s[0] * s[1])
    plan = tight_eng.policy.plan_fc(64, n, k, act_bytes=4, weight_bytes=4,
                                    regime="sa_fc")
    assert tight.microbatch == plan.bb


def test_server_drains_queue_in_waves(alexnet_params):
    """More requests than one micro-batch: the queue drains in
    planner-sized waves, preserving order and per-request identity."""
    eng = Engine(backend="pallas", interpret=True,
                 policy=DispatchPolicy(vmem_budget=200 * 1024))
    srv = CNNServer("alexnet", alexnet_params, in_res=RES, width_mult=WIDTH,
                    max_batch=4, engine=eng)
    srv.microbatch = 2                      # force small waves for the test
    reqs = _requests(5, seed=2)
    for r in reqs:
        srv.submit(r)
    done = srv.run()
    assert len(done) == 5
    assert [w.batch for w in srv.waves] == [2, 2, 1]
    assert [u for w in srv.waves for u in w.uids] == [0, 1, 2, 3, 4]
    assert all(r.logits is not None and r.logits.shape == (1000,)
               for r in done)


def test_server_rejects_wrong_shape(alexnet_params):
    srv = CNNServer("alexnet", alexnet_params, in_res=RES, width_mult=WIDTH)
    with pytest.raises(ValueError, match="image shape"):
        srv.submit(CNNRequest(uid=0, image=np.zeros((5, 5, 3), np.float32)))


def test_server_run_on_empty_queue_is_a_noop(alexnet_params):
    """Edge case: draining a server nobody submitted to returns [] (both
    entries, both modes) and files no waves."""
    srv = CNNServer("alexnet", alexnet_params, in_res=RES, width_mult=WIDTH)
    assert srv.run() == []
    assert srv.run(pipelined=False) == []
    assert srv.step_wave() == []
    assert srv.drain() == []
    assert srv.waves == []


def test_server_final_wave_smaller_than_planner_microbatch(alexnet_params):
    """Edge case: a queue that is not a multiple of FCPlan.bb ends with a
    partial wave — it still dispatches (smaller batch variant) and its
    logits match the unbatched forward bitwise."""
    srv = CNNServer("alexnet", alexnet_params, in_res=RES, width_mult=WIDTH,
                    max_batch=8)
    assert srv.preferred_microbatch == 8
    reqs = _requests(3, seed=3)             # 3 < bb: one partial wave
    for r in reqs:
        srv.submit(r)
    done = srv.run()
    assert len(done) == 3
    assert [w.batch for w in srv.waves] == [3]
    eng = Engine(backend="pallas", interpret=True)
    single = cnn.cnn_forward("alexnet", alexnet_params,
                             jnp.asarray(reqs[0].image)[None], eng=eng)
    np.testing.assert_array_equal(np.asarray(single)[0], reqs[0].logits)


def test_server_rejects_duplicate_uids(alexnet_params):
    """Edge case: uids name one request for the server's lifetime —
    resubmitting one raises, even after the original already completed."""
    srv = CNNServer("alexnet", alexnet_params, in_res=RES, width_mult=WIDTH)
    srv.submit(_requests(1)[0])
    with pytest.raises(ValueError, match="duplicate request uid 0"):
        srv.submit(_requests(1)[0])
    srv.run()
    with pytest.raises(ValueError, match="duplicate request uid 0"):
        srv.submit(_requests(1)[0])


def test_server_step_wave_and_drain(alexnet_params):
    """The zoo-facing wave-executor API: step_wave() serves exactly one
    micro-batch per call; drain() flushes the tail (including a final
    partial wave)."""
    srv = CNNServer("alexnet", alexnet_params, in_res=RES, width_mult=WIDTH,
                    max_batch=4)
    srv.microbatch = 2
    reqs = _requests(5, seed=4)
    for r in reqs:
        srv.submit(r)
    first = srv.step_wave()
    assert [r.uid for r in first] == [0, 1]
    assert len(srv.queue) == 3
    rest = srv.drain()
    assert [r.uid for r in rest] == [2, 3, 4]
    assert [w.batch for w in srv.waves] == [2, 2, 1]
    assert all(r.done for r in reqs)


def test_server_preferred_microbatch_is_planner_pinned(alexnet_params):
    """preferred_microbatch is the immutable planner answer; microbatch
    is the mutable admission cap initialized from it."""
    srv = CNNServer("alexnet", alexnet_params, in_res=RES, width_mult=WIDTH,
                    max_batch=8)
    assert srv.microbatch == srv.preferred_microbatch == 8
    srv.microbatch = 2
    assert srv.preferred_microbatch == 8    # the planner's answer persists
