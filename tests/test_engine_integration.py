"""Integration: MPNA's heterogeneous dispatch inside full models.

The paper's claim is that CONV-like (compute-bound) and FC-like
(bandwidth-bound) operators need different dataflows.  These tests assert
the engine actually routes a transformer's train/prefill matmuls to the
SA-CONV regime and its decode matmuls to SA-FC — per-operator, from
arithmetic intensity, with no per-model special-casing."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.core import engine
from repro.distributed.pipeline import PipeSchedule
from repro.models import transformer as T
from repro.serve import kvcache as KC
from repro.serve.serve_step import decode_step

CFG = ModelConfig(name="disp", family="dense", n_layers=2, d_model=512,
                  n_heads=8, n_kv_heads=4, d_ff=2048, vocab_size=8192,
                  head_dim=64, param_dtype="bfloat16",
                  compute_dtype="bfloat16")

# production-scale dims for the train-side assertion (eval_shape only — no
# allocation): at toy widths the GQA kv projections are genuinely
# low-intensity and correctly route sa_fc
CFG_BIG = ModelConfig(name="disp-big", family="dense", n_layers=2,
                      d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
                      vocab_size=32000, head_dim=128,
                      param_dtype="bfloat16", compute_dtype="bfloat16")


def test_train_matmuls_route_sa_conv():
    params = jax.eval_shape(
        lambda: T.init_params(CFG_BIG, jax.random.PRNGKey(0)))
    tokens = jax.ShapeDtypeStruct((16, 2048), jnp.int32)
    with engine.dispatch_trace() as tr:
        jax.eval_shape(lambda p, t: T.loss_fn(CFG_BIG, p, {"tokens": t}),
                       params, tokens)
    mm = [t for t in tr if t["regime"] in ("sa_conv", "sa_fc")]
    assert mm, "no matmuls traced"
    frac = sum(t["regime"] == "sa_conv" for t in mm) / len(mm)
    assert frac == 1.0, f"train should be compute-bound; got {frac:.0%}"


def test_decode_matmuls_route_sa_fc():
    params = T.init_params(CFG, jax.random.PRNGKey(0))
    cache = KC.init_cache(CFG, 4, 128, dtype=jnp.bfloat16)
    tok = jnp.zeros((4, 1), jnp.int32)
    with engine.dispatch_trace() as tr:
        jax.eval_shape(lambda p, c, t: decode_step(CFG, p, c, t,
                                                   jnp.int32(7)),
                       params, cache, tok)
    mm = [t for t in tr if t["regime"] in ("sa_conv", "sa_fc")]
    assert mm
    frac = sum(t["regime"] == "sa_fc" for t in mm) / len(mm)
    assert frac == 1.0, f"decode is the SA-FC regime; got {frac:.0%}"


def test_regime_flips_with_batch():
    """The same operator flips regime as reuse grows — MPNA's Fig. 6
    observation that reuse, not layer type, is the discriminator."""
    w = jnp.zeros((4096, 4096), jnp.bfloat16)
    with engine.dispatch_trace() as tr:
        engine.matmul(jnp.zeros((4, 4096), jnp.bfloat16), w, name="op")
        engine.matmul(jnp.zeros((16384, 4096), jnp.bfloat16), w, name="op")
    assert tr[0]["regime"] == "sa_fc"
    assert tr[1]["regime"] == "sa_conv"


# ---------------------------------------------------------------------------
# pipeline schedule (pod-axis PP)
# ---------------------------------------------------------------------------
def test_pipe_schedule_bubble():
    s = PipeSchedule(stages=2, microbatches=8)
    assert s.bubble_fraction == pytest.approx(1 / 9)
    slots = s.slots()
    assert len(slots) == 9                       # M + S - 1 ticks
    # every (stage, mb) executes exactly once
    seen = [sm for row in slots for sm in row]
    assert sorted(seen) == [(st, mb) for st in range(2) for mb in range(8)] \
        or len(seen) == 16


def test_pipe_schedule_causality():
    """Stage s never processes microbatch m before stage s-1 did."""
    s = PipeSchedule(stages=4, microbatches=6)
    done_at = {}
    for t, row in enumerate(s.slots()):
        for stage, mb in row:
            done_at[(stage, mb)] = t
    for stage in range(1, 4):
        for mb in range(6):
            assert done_at[(stage, mb)] > done_at[(stage - 1, mb)]
