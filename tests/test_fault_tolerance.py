"""Fault-tolerance primitive edge cases: StepMonitor warmup boundary and
window eviction, HeartbeatTracker deadline semantics + late
registration, StepDeadline.  These primitives feed the zoo serving
plane's health state machine, so their boundary behavior is contractual."""
from __future__ import annotations

import pytest

from repro.distributed.fault_tolerance import (Heartbeat,
                                               HeartbeatTracker,
                                               StepDeadline, StepMonitor,
                                               UnknownNodeError)

# -- StepMonitor -------------------------------------------------------------


def test_step_monitor_never_flags_during_warmup():
    mon = StepMonitor(factor=3.0, warmup=5, window=50)
    # wildly anomalous steps inside the warmup window are still "ok" —
    # there is no trustworthy median yet
    for step in range(5):
        assert mon.observe(step, 1000.0 * (step + 1)) == "ok"


def test_step_monitor_flags_exactly_after_warmup():
    mon = StepMonitor(factor=3.0, warmup=3, window=50)
    for step in range(3):
        assert mon.observe(step, 1.0) == "ok"
    # observation #warmup is the first that CAN be flagged
    assert mon.observe(3, 3.0) == "ok"        # 3.0 == factor*median: not >
    assert mon.observe(4, 3.0001) == "straggler"


def test_step_monitor_straggler_samples_do_not_poison_median():
    mon = StepMonitor(factor=3.0, warmup=3, window=50)
    for step in range(3):
        mon.observe(step, 1.0)
    before = mon.median()
    assert mon.observe(3, 100.0) == "straggler"
    # the outlier was NOT added to the window: the median is unchanged
    # and the next healthy step is still judged against it
    assert mon.median() == before
    assert mon.observe(4, 1.0) == "ok"
    assert mon.observe(5, 100.0) == "straggler"


def test_step_monitor_window_evicts_oldest():
    mon = StepMonitor(factor=3.0, warmup=2, window=4)
    # four slow-but-accepted steps, then four fast ones: the fast steps
    # evict the slow era entirely (window=4), so the median adapts and a
    # once-normal slow step becomes a straggler
    for step in range(4):
        assert mon.observe(step, 10.0) == "ok"
    for step in range(4, 8):
        assert mon.observe(step, 1.0) == "ok"
    assert mon.median() == 1.0
    assert mon.observe(8, 10.0) == "straggler"


def test_step_monitor_empty_median_is_nan():
    import math
    assert math.isnan(StepMonitor().median())


# -- HeartbeatTracker --------------------------------------------------------


def test_heartbeat_exactly_at_deadline_is_alive():
    hb = HeartbeatTracker(["a"], timeout=10.0, now=0.0)
    # the contract is STRICTLY greater than timeout: a node last seen
    # exactly `timeout` seconds ago is still alive
    assert hb.failed(now=10.0) == []
    assert hb.survivors(now=10.0) == ["a"]
    assert hb.failed(now=10.0 + 1e-9) == ["a"]
    assert hb.survivors(now=10.0 + 1e-9) == []


def test_heartbeat_empty_survivors_and_empty_tracker():
    hb = HeartbeatTracker(["a", "b"], timeout=1.0, now=0.0)
    assert hb.survivors(now=100.0) == []          # everyone timed out
    none = HeartbeatTracker([], timeout=1.0, now=0.0)
    assert none.nodes() == ()
    assert none.failed(now=100.0) == []           # nothing to fail
    assert none.survivors(now=100.0) == []


def test_heartbeat_unknown_node_raises_typed_error():
    hb = HeartbeatTracker(["a"], timeout=1.0, now=0.0)
    with pytest.raises(UnknownNodeError) as ei:
        hb.beat("ghost", now=1.0)
    assert ei.value.node == "ghost"
    assert ei.value.known == ("a",)
    assert "register()" in str(ei.value)
    assert isinstance(ei.value, KeyError)         # back-compat catch sites


def test_heartbeat_late_registration_enables_beat():
    hb = HeartbeatTracker(["a"], timeout=5.0, now=0.0)
    hb.register("b", now=3.0)                     # elastic scale-up
    assert hb.nodes() == ("a", "b")
    hb.beat("b", now=4.0)                         # no longer raises
    # "a" heartbeated at 0.0, "b" at 4.0: at t=6 only "a" is dead
    assert hb.failed(now=6.0) == ["a"]
    # re-registering an existing node just refreshes its heartbeat
    hb.register("a", now=6.0)
    assert hb.failed(now=6.0) == []


def test_heartbeat_deregister_mirrors_register():
    hb = HeartbeatTracker(["a", "b"], timeout=5.0, now=0.0)
    hb.deregister("b")                            # elastic scale-down
    assert hb.nodes() == ("a",)
    # a deregistered node stops tripping failed()/shrinking survivors()
    assert hb.failed(now=100.0) == ["a"]
    assert hb.survivors(now=100.0) == []
    with pytest.raises(UnknownNodeError):
        hb.beat("b", now=1.0)                     # really gone
    # register() round-trips it back in (scale-up after scale-down)
    hb.register("b", now=100.0)
    assert hb.nodes() == ("a", "b")
    assert hb.survivors(now=100.0) == ["b"]


def test_heartbeat_deregister_unknown_node_raises_typed_error():
    hb = HeartbeatTracker(["a"], timeout=1.0, now=0.0)
    with pytest.raises(UnknownNodeError) as ei:
        hb.deregister("ghost")
    assert ei.value.node == "ghost"
    assert ei.value.known == ("a",)
    # deregister consumes the node: a second call is an error too
    hb.deregister("a")
    with pytest.raises(UnknownNodeError):
        hb.deregister("a")
    assert hb.nodes() == ()


def test_heartbeat_modeled_clock_never_touches_wall_clock():
    hb = HeartbeatTracker(["n"], timeout=2.0, now=100.0)
    assert hb._beats["n"] == Heartbeat("n", 100.0)
    hb.beat("n", now=101.0)
    assert hb.failed(now=103.0) == []
    assert hb.failed(now=103.0 + 1e-6) == ["n"]


# -- StepDeadline ------------------------------------------------------------


def test_step_deadline_not_expired_before_begin():
    sd = StepDeadline(deadline_s=1.0)
    assert not sd.expired(now=1e9)                # never began
    sd.begin()
    assert not sd.expired()
