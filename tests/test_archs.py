"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture instantiates a REDUCED same-family config and
runs one forward + train-grad step and one decode step on CPU, asserting
output shapes and absence of NaNs.  Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import reduced
from repro.configs.registry import all_lm_configs
from repro.models import transformer as T
from repro.serve import kvcache as KC

ARCHS = sorted(all_lm_configs())
S = 32
B = 2


def _small(arch):
    cfg = all_lm_configs()[arch]
    cfg = reduced(cfg, param_dtype="float32", compute_dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    return cfg


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if cfg.vision_tokens:
        batch["vision_embeds"] = jax.random.normal(
            ks[1], (B, cfg.vision_tokens, cfg.frontend_dim), jnp.float32)
    if cfg.enc_dec:
        batch["audio_embeds"] = jax.random.normal(
            ks[2], (B, cfg.audio_frames, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = _small(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, aux, _ = jax.jit(
        lambda p, b: T.forward(cfg, p, b))(params, batch)
    seq = S + (cfg.vision_tokens or 0)
    assert logits.shape == (B, seq, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), "NaN/inf in logits"

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, b: T.loss_fn(cfg, p, b),
                           has_aux=True))(params, batch)
    assert jnp.isfinite(loss)
    for leaf in jax.tree.leaves(grads):
        assert jnp.isfinite(leaf).all(), "NaN/inf in grads"
    # one SGD step must change the loss (the graph is actually wired)
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype),
                           params, grads)
    loss2, _ = T.loss_fn(cfg, params2, batch)
    assert jnp.isfinite(loss2) and loss2 != loss


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = _small(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    tokens = batch["tokens"]

    logits, _, _ = T.forward(cfg, params, batch)
    pre = dict(batch, tokens=tokens[:, :S - 1])
    _, _, pcache = T.forward(cfg, params, pre, mode="prefill")
    cache = KC.cache_from_prefill(cfg, pcache, max_seq=S + 8,
                                  dtype=jnp.float32)
    vt = cfg.vision_tokens or 0
    dlog, _ = T.decode_step(cfg, params, cache, tokens[:, S - 1:S],
                            jnp.int32(S - 1 + vt))
    assert dlog.shape == (B, 1, cfg.vocab_size)
    import numpy as np
    np.testing.assert_allclose(dlog[:, 0], logits[:, -1],
                               rtol=5e-4, atol=5e-4)
