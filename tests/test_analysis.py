"""Static-analysis subsystem tests (``repro.analysis``).

Two halves:

* clean-path: every zoo schedule variant verifies with zero findings and
  zero kernel execution, and the registry/engine debug hooks accept them;
* seeded-mutation self-tests: corrupt one plan field (or one source
  line) at a time and assert the verifier catches exactly that
  corruption with a precise diagnostic — a verifier that cannot fail
  verifies nothing.
"""
import dataclasses
import textwrap

import pytest

from repro.analysis import (
    AnalysisReport,
    Finding,
    ScheduleVerificationError,
    context_for,
    lint_scheduler_sources,
    merge_reports,
    verify_context,
    verify_stage_pair,
)
from repro.analysis.determinism import DEFAULT_TARGETS, lint_file
from repro.analysis.passes import (
    check_accounting,
    check_coverage,
    check_races,
    check_residency,
)
from repro.core.dataflow import MAX_TILE, ConvPlan, FCPlan
from repro.core.engine import Engine
from repro.core.schedule import ScheduleRegistry


# -- shared compiled schedule (memoized; compiled once per process) ----------

@pytest.fixture(scope="module")
def alexnet_pair():
    return ScheduleRegistry().register("alexnet", batch=1)


@pytest.fixture(scope="module")
def fc_ctx(alexnet_pair):
    """Context of one batch-amortized FC entry of the fc stage."""
    _, fc_sched = alexnet_pair
    for key, plan in fc_sched.items():
        if isinstance(plan, FCPlan):
            return context_for(key, plan, fc_sched.policy)
    raise AssertionError("alexnet fc stage holds no FCPlan")


@pytest.fixture(scope="module")
def conv_ctx(alexnet_pair):
    conv_sched, _ = alexnet_pair
    key, plan = next(iter(conv_sched.conv_entries.items()))
    assert isinstance(plan, ConvPlan)
    return context_for(key, plan, conv_sched.policy)


def _mutate(ctx, **plan_fields):
    """Rebuild the context around a plan with one corrupted field."""
    bad_plan = dataclasses.replace(ctx.plan, **plan_fields)
    return context_for(ctx.key, bad_plan, ctx.policy)


def _messages(findings):
    return " | ".join(f.message for f in findings)


# -- clean path --------------------------------------------------------------

def test_alexnet_schedule_verifies_clean(alexnet_pair):
    report = verify_stage_pair(alexnet_pair, label="alexnet@b1")
    assert report.ok, report.summary()
    assert report.checked_ops == 8
    assert report.findings == []


def test_clean_contexts_pass_every_pass(fc_ctx, conv_ctx):
    for ctx in (fc_ctx, conv_ctx):
        assert verify_context(ctx) == []


def test_determinism_lint_clean_on_repo_sources():
    report = lint_scheduler_sources()
    assert report.ok, report.summary()
    assert report.checked_files == len(DEFAULT_TARGETS) == 5


# -- seeded mutations: coverage ----------------------------------------------

def test_coverage_catches_misaligned_batch_tile(fc_ctx):
    findings = check_coverage(_mutate(fc_ctx, bb=24))
    assert findings, "verifier missed a 24-row (non-SUBLANE) batch tile"
    msgs = _messages(findings)
    assert "SUBLANE" in msgs
    assert "normalized tiles" in msgs      # plan-vs-kernel clamp drift


def test_coverage_catches_max_tile_overflow(fc_ctx):
    assert fc_ctx.plan.n >= 2 * MAX_TILE, "pick a wider FC layer"
    findings = check_coverage(_mutate(fc_ctx, bn=2 * MAX_TILE))
    assert any(f"exceeds MAX_TILE={MAX_TILE}" in f.message
               for f in findings), _messages(findings)


def test_coverage_catches_grid_gap(fc_ctx):
    """A grid shrunk below the plan's own grid is both a plan/kernel
    grid disagreement and (on the shrunken axis) a coverage gap."""
    geom = fc_ctx.geom
    shrunk = dataclasses.replace(
        geom, grid=(geom.grid[0], geom.grid[1], geom.grid[2] - 1))
    bad = dataclasses.replace(fc_ctx, geom=shrunk)
    msgs = _messages(check_coverage(bad))
    assert "kernel grid" in msgs and "!= plan grid" in msgs
    assert "silent clamp" in msgs or "coverage gap" in msgs


# -- seeded mutations: residency ---------------------------------------------

def test_residency_catches_vmem_lie(fc_ctx, conv_ctx):
    for ctx in (fc_ctx, conv_ctx):
        findings = check_residency(
            _mutate(ctx, vmem_bytes=ctx.plan.vmem_bytes + 1))
        assert len(findings) == 1
        assert "plan and kernel disagree" in findings[0].message
        assert str(ctx.plan.vmem_bytes + 1) in findings[0].message


# -- seeded mutations: races -------------------------------------------------

def test_race_catches_parallel_reduction_dim(fc_ctx):
    """Re-labelling the FC reduction grid dim 'parallel' makes every
    accumulation step a racing writer of its output block."""
    geom = dataclasses.replace(
        fc_ctx.geom,
        dimension_semantics=("parallel",) * len(fc_ctx.geom.grid))
    findings = check_races(dataclasses.replace(fc_ctx, geom=geom))
    assert any("write race" in f.message for f in findings), \
        _messages(findings)


def test_race_catches_non_innermost_reduction(fc_ctx):
    sem = ("arbitrary",) + ("parallel",) * (len(fc_ctx.geom.grid) - 1)
    geom = dataclasses.replace(fc_ctx.geom, dimension_semantics=sem)
    findings = check_races(dataclasses.replace(fc_ctx, geom=geom))
    assert any("innermost-sequential suffix" in f.message
               for f in findings), _messages(findings)


# -- seeded mutations: accounting --------------------------------------------

def test_accounting_catches_traffic_lie(fc_ctx, conv_ctx):
    for ctx in (fc_ctx, conv_ctx):
        findings = check_accounting(
            _mutate(ctx, hbm_bytes=ctx.plan.hbm_bytes + 64))
        assert any("!= plan.hbm_bytes" in f.message for f in findings), \
            _messages(findings)


def test_accounting_catches_weight_stream_lie(fc_ctx):
    bad = _mutate(fc_ctx,
                  weight_hbm_bytes=fc_ctx.plan.weight_hbm_bytes + 4)
    findings = check_accounting(bad)
    assert any("plan.weight_hbm_bytes" in f.message for f in findings), \
        _messages(findings)


def test_accounting_catches_flip_batch_lie(fc_ctx):
    bad = _mutate(fc_ctx, flip_batch=fc_ctx.plan.flip_batch + 7)
    findings = check_accounting(bad)
    assert any("plan.flip_batch" in f.message for f in findings), \
        _messages(findings)


def test_accounting_catches_bad_case(fc_ctx):
    findings = check_accounting(_mutate(fc_ctx, case=5))
    assert any("outside 1..4" in f.message for f in findings)


def test_accounting_catches_conv_flops_lie(conv_ctx):
    bad = _mutate(conv_ctx, flops=conv_ctx.plan.flops - 2)
    findings = check_accounting(bad)
    assert any("plan.flops" in f.message for f in findings), \
        _messages(findings)


# -- seeded mutations: determinism lint --------------------------------------

def _lint_snippet(tmp_path, source, **kw):
    path = tmp_path / "snippet.py"
    path.write_text(textwrap.dedent(source))
    return lint_file(path, rel="snippet.py", **kw)


def test_determinism_flags_wall_clock(tmp_path):
    findings = _lint_snippet(tmp_path, """\
        import time
        def decide():
            return time.perf_counter()
        """)
    assert len(findings) == 1
    assert "wall-clock call time.perf_counter()" in findings[0].message
    assert findings[0].op == "snippet.py:3"


def test_determinism_pragma_and_exemption(tmp_path):
    source = """\
        import time
        def measure():
            return time.time()
        def decide():
            return time.time()  # det: allow
        """
    assert _lint_snippet(tmp_path, source) != []  # measure() flagged...
    assert _lint_snippet(tmp_path, source,
                         exempt=frozenset({"measure"})) == []


def test_determinism_flags_unseeded_rng_only(tmp_path):
    findings = _lint_snippet(tmp_path, """\
        import numpy as np
        def draw():
            good = np.random.default_rng(1234)
            bad = np.random.default_rng()
            worse = np.random.poisson(3.0)
            return good, bad, worse
        """)
    assert len(findings) == 2
    assert "without a seed" in findings[0].message
    assert "global" in findings[1].message


def test_determinism_flags_set_iteration(tmp_path):
    findings = _lint_snippet(tmp_path, """\
        def order(queues):
            for q in set(queues):
                yield q
            return [x for x in {1, 2}] + list({3, 4})
        """)
    kinds = _messages(findings)
    assert "for-loop over an unordered set" in kinds
    assert "comprehension over an unordered set" in kinds
    assert "list() over an unordered set" in kinds


# -- report / error types ----------------------------------------------------

def test_finding_validates_pass_name_and_severity():
    with pytest.raises(ValueError, match="unknown pass"):
        Finding("typo", "op", "msg")
    with pytest.raises(ValueError, match="severity"):
        Finding("coverage", "op", "msg", severity="fatal")


def test_report_merge_and_raise():
    bad = AnalysisReport(label="b", checked_ops=1)
    bad.findings.append(Finding("residency", "fc1", "working set lie"))
    warn = AnalysisReport(label="w", checked_ops=1)
    warn.findings.append(Finding("coverage", "big", "skipped",
                                 severity="warning"))
    merged = merge_reports("all", [bad, warn])
    assert merged.checked_ops == 2
    assert len(merged.errors) == 1 and len(merged.warnings) == 1
    assert not merged.ok
    with pytest.raises(ScheduleVerificationError,
                       match="working set lie") as ei:
        merged.raise_if_failed()
    assert ei.value.report is merged
    assert warn.ok  # warnings alone do not fail a report
    warn.raise_if_failed()


# -- registry conflict detection + debug hooks -------------------------------

def test_registry_rejects_conflicting_reregistration():
    reg = ScheduleRegistry()
    pair = reg.register("alexnet", batch=1)
    assert reg.register("alexnet", batch=1) is pair  # idempotent
    with pytest.raises(ValueError, match="conflicting re-registration"):
        reg.register("alexnet", batch=1, width_mult=0.5)
    assert len(reg) == 1  # the filed pair survived the rejected call


def test_registry_verify_hook_accepts_clean_schedules(alexnet_pair):
    reg = ScheduleRegistry(verify=True)
    assert reg.register("alexnet", batch=1) == alexnet_pair


class _StubSchedule:
    """Minimal LayerSchedule facade holding one corrupted entry."""
    phase = "fc"

    def __init__(self, ctx):
        self.policy = ctx.policy
        self.conv_entries = {}
        self._entries = {ctx.key: dataclasses.replace(
            ctx.plan, vmem_bytes=ctx.plan.vmem_bytes + 1)}

    def items(self):
        return self._entries.items()


def test_engine_verify_hook(alexnet_pair, fc_ctx):
    _, fc_sched = alexnet_pair
    eng = Engine(backend="pallas", verify_schedules=True)
    derived = eng.with_schedule(fc_sched)        # clean: attaches fine
    assert derived.verify_schedules and derived.schedule is fc_sched
    with pytest.raises(ScheduleVerificationError,
                       match="plan and kernel disagree"):
        eng.with_schedule(_StubSchedule(fc_ctx))
    # the hook is opt-in: a default engine attaches without verifying
    Engine(backend="pallas").with_schedule(_StubSchedule(fc_ctx))


# -- CLI ---------------------------------------------------------------------

def test_cli_verifies_named_net(capsys):
    from repro.analysis.__main__ import main
    assert main(["--net", "alexnet", "--skip-determinism-lint"]) == 0
    out = capsys.readouterr().out
    assert "[alexnet@b1] OK" in out
    assert "0 findings" in out


def test_cli_requires_a_target():
    from repro.analysis.__main__ import main
    with pytest.raises(SystemExit):
        main([])
